"""Flight recorder: a ring buffer of recent control-plane state that is
dumped when something goes wrong.

The QoS plane appends one `note()` per tick with the per-class /
per-shard state worth having at an incident (rungs, canary estimates,
drift, thresholds); the serving engine `amend()`s the same entry with
tick latency and occupancy once the tick completes. On a hard precise
fallback or a monitor violation, `trip(reason, ...)` freezes the last N
entries into a dump -- kept in memory for tests and post-hoc analysis,
and written to `<out_dir>/flight_<seq>_<reason>.json` when an output
directory is configured.

Unlike tracing, the recorder is cheap enough to leave ALWAYS ON for the
QoS plane (one small dict append per tick on the host; the ring is
bounded), so the dump exists even for runs nobody thought to trace --
that is the point of a flight recorder. Format documented in
docs/observability.md.
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import Any, Deque, Dict, List, Optional

DUMP_SCHEMA_VERSION = 1


class FlightRecorder:
    """Bounded ring of per-tick state snapshots + trip dumps."""

    def __init__(self, capacity: int = 64, out_dir: Optional[str] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.out_dir = out_dir
        self._ring: Deque[Dict[str, Any]] = deque(maxlen=capacity)
        self.dumps: List[Dict[str, Any]] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._ring)

    def note(self, **state) -> None:
        """Append one tick's state snapshot to the ring."""
        self._ring.append(dict(state))

    def amend(self, **fields) -> None:
        """Merge fields into the most recent note (the serving engine
        closes out the entry the QoS plane opened). No-op on an empty
        ring so callers need no ordering guard."""
        if self._ring:
            self._ring[-1].update(fields)

    def window(self) -> List[Dict[str, Any]]:
        """Current ring contents, oldest first."""
        return list(self._ring)

    def trip(self, reason: str, **context) -> Dict[str, Any]:
        """Freeze the ring into a dump. The ring is NOT cleared: an
        incident right after another still sees the shared lead-up."""
        self._seq += 1
        dump = {
            "schema": DUMP_SCHEMA_VERSION,
            "seq": self._seq,
            "reason": reason,
            "context": dict(context),
            "ticks": self.window(),
        }
        self.dumps.append(dump)
        if self.out_dir:
            os.makedirs(self.out_dir, exist_ok=True)
            path = os.path.join(
                self.out_dir, f"flight_{self._seq:04d}_{reason}.json")
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(dump, f, indent=2, default=str)
            os.replace(tmp, path)
            dump["path"] = path
        return dump


_RECORDER: Optional[FlightRecorder] = None


def get_recorder() -> Optional[FlightRecorder]:
    return _RECORDER


def install(capacity: int = 64,
            out_dir: Optional[str] = None) -> FlightRecorder:
    """Install a process-global recorder (what QosEngine/ServingEngine
    write to when none was passed explicitly)."""
    global _RECORDER
    _RECORDER = FlightRecorder(capacity=capacity, out_dir=out_dir)
    return _RECORDER


def uninstall() -> Optional[FlightRecorder]:
    global _RECORDER
    r, _RECORDER = _RECORDER, None
    return r
