"""Span-based event tracing with Chrome/Perfetto `trace_event` export.

One module-global `Tracer` (installed with `enable()` / scoped with
`use()`) buffers three record kinds, all in the Chrome Trace Event
format (https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU)
so a saved file opens directly in `ui.perfetto.dev` or `chrome://tracing`:

  span(name, **args)     -- a timed region ("X" complete events with
                            microsecond ts/dur), used as a context manager;
  event(name, **args)    -- an instant ("i") event: QoS decisions, knob
                            moves, canary scores;
  counter(name, value)   -- a cumulative counter ("C" events): cache hits,
                            recompiles, canary ticks.

**Zero-cost-when-disabled contract.** With no tracer installed (the
default), `span()` returns a shared no-op context manager and `event()`/
`counter()` return immediately after one module-attribute read -- no
allocation beyond the kwargs dict, no locking, no time syscalls. Nothing
here may ever force a device->host transfer: payloads are stored AS GIVEN
(never `np.asarray`'d), which is also what lets lint rule A008 detect a
traced value leaking into an event payload (`docs/analysis.md`). The
serving tick's instrumentation rides this contract -- see the
`_cache_size()` + throughput-ratio regression gates in `tests/test_obs.py`
and `benchmarks/obs_overhead.py`.

Buffering is thread-safe (one lock around the append; `tid` records the
emitting thread) so the harness's thread-pool sweeps trace correctly.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Dict, List, Optional

# The single active tracer. Read (not locked) on every span()/event()/
# counter() call -- module attribute reads are atomic in CPython, and the
# only mutation is install/uninstall.
_TRACER: Optional["Tracer"] = None


class _NullSpan:
    """Shared no-op context manager returned by `span()` when disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """A live timed region: records one "X" complete event on exit."""

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer._complete(self.name, self._t0, time.perf_counter(),
                               self.args)
        return False


class Tracer:
    """Thread-safe buffer of Chrome trace events.

    Timestamps are microseconds relative to the tracer's construction
    (`perf_counter` deltas -- monotonic, sub-microsecond resolution).
    """

    def __init__(self):
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._records: List[Dict] = []
        self._counters: Dict[str, float] = {}
        self._pid = os.getpid()

    # -- record sinks (called by the module-level API) -------------------

    def _us(self, t: float) -> float:
        return (t - self._epoch) * 1e6

    def _complete(self, name: str, t0: float, t1: float,
                  args: Dict) -> None:
        rec = {"name": name, "ph": "X", "ts": self._us(t0),
               "dur": (t1 - t0) * 1e6, "pid": self._pid,
               "tid": threading.get_ident()}
        if args:
            rec["args"] = args
        with self._lock:
            self._records.append(rec)

    def _instant(self, name: str, args: Dict) -> None:
        rec = {"name": name, "ph": "i", "s": "t",
               "ts": self._us(time.perf_counter()), "pid": self._pid,
               "tid": threading.get_ident()}
        if args:
            rec["args"] = args
        with self._lock:
            self._records.append(rec)

    def _count(self, name: str, value: float) -> None:
        with self._lock:
            total = self._counters.get(name, 0.0) + value
            self._counters[name] = total
            self._records.append({
                "name": name, "ph": "C",
                "ts": self._us(time.perf_counter()), "pid": self._pid,
                "tid": threading.get_ident(), "args": {"value": total}})

    # -- inspection ------------------------------------------------------

    @property
    def records(self) -> List[Dict]:
        """Snapshot of the buffered records (copy: safe to iterate while
        other threads keep tracing)."""
        with self._lock:
            return list(self._records)

    def counter_value(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    # -- export ----------------------------------------------------------

    def to_chrome(self) -> Dict:
        """The Perfetto/chrome://tracing document: an object with a
        `traceEvents` list (the "JSON Object Format", which both UIs
        accept and which leaves room for metadata)."""
        return {
            "traceEvents": self.records,
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs",
                          "schema": SCHEMA_VERSION},
        }

    def save(self, path: str) -> str:
        """Write the Chrome trace JSON. Non-JSON payload values fall back
        to `str()` -- a weird payload must never lose the whole trace."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_chrome(), f, default=str)
        os.replace(tmp, path)
        return path


SCHEMA_VERSION = 1


# --------------------------------------------------------------------------
# module-level API (what instrumented code calls)
# --------------------------------------------------------------------------

def enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def enable(tracer: Optional[Tracer] = None) -> Tracer:
    """Install `tracer` (or a fresh one) as the active tracer."""
    global _TRACER
    _TRACER = tracer if tracer is not None else Tracer()
    return _TRACER


def disable() -> Optional[Tracer]:
    """Uninstall and return the active tracer (None if none was active)."""
    global _TRACER
    t, _TRACER = _TRACER, None
    return t


@contextlib.contextmanager
def use(tracer: Optional[Tracer] = None):
    """Scoped tracing: install for the block, restore the previous tracer
    after (tests and the A008 lint probe trace this way)."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer if tracer is not None else Tracer()
    try:
        yield _TRACER
    finally:
        _TRACER = prev


def span(name: str, **args) -> "_Span":
    """Timed region context manager; a shared no-op when disabled."""
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return _Span(t, name, args)


def event(name: str, **args) -> None:
    """Instant event (QoS decision, knob move, canary score, ...)."""
    t = _TRACER
    if t is None:
        return
    t._instant(name, args)


def counter(name: str, value: float = 1.0) -> None:
    """Increment a cumulative trace counter by `value`."""
    t = _TRACER
    if t is None:
        return
    t._count(name, value)
