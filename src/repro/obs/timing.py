"""The repo's ONE warm-up/median-of-k wall-clock measurement helper.

Four call sites used to hand-roll the same loop with slightly different
bugs waiting to happen (`kernels/tuning.py`, `analysis/machine.py`,
`core/batching.py`, `qos/calibrate.py`); they all route through
`measure()` now. The semantics every caller needs:

  * each warm-up AND timed call is forced with `jax.block_until_ready`,
    so async dispatch never hides device time;
  * the reported statistic defaults to the median of `repeats` timed
    calls (robust to one-off scheduler noise); `stat="min"` gives the
    best-of-N the sweep harness's `_timed` uses;
  * when tracing is enabled, each measurement emits one span named by
    `span` (or "obs.measure") carrying the per-repeat times.

`warmup=0, repeats=1` degenerates to a plain timed call, which is what
`qos/calibrate.py` needs around its already-warm decode loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

import jax

from repro.obs import trace


@dataclass(frozen=True)
class Measurement:
    """Result of `measure()`: the chosen statistic, the raw per-repeat
    times, and the value returned by the final timed call."""

    seconds: float
    times: Tuple[float, ...] = field(default=())
    value: Any = None


def _stat(times: List[float], stat: str) -> float:
    if stat == "median":
        s = sorted(times)
        return s[len(s) // 2]
    if stat == "min":
        return min(times)
    if stat == "mean":
        return sum(times) / len(times)
    raise ValueError(f"unknown stat {stat!r} (median|min|mean)")


def measure(fn: Callable, *args, warmup: int = 2, repeats: int = 5,
            stat: str = "median", span: Optional[str] = None,
            **kwargs) -> Measurement:
    """Time `fn(*args, **kwargs)` with warm-up and `block_until_ready`
    forcing; return the `stat` over `repeats` timed calls.

    `repeats=0` is allowed only as "warm but don't time" and reports
    seconds=0.0 with no samples (used when a caller wants the warm-up
    discipline without a measurement).
    """
    value = None
    for _ in range(warmup):
        value = jax.block_until_ready(fn(*args, **kwargs))
    times: List[float] = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        value = jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    seconds = _stat(times, stat) if times else 0.0
    if trace.enabled():
        with trace.span(span or "obs.measure", warmup=warmup,
                        repeats=repeats, stat=stat, seconds=seconds,
                        times=list(times)):
            pass
    return Measurement(seconds=seconds, times=tuple(times), value=value)
