"""Typed counters, gauges, and histograms with one snapshot schema.

The registry is process-ambient and ALWAYS ON for coarse call sites (one
increment per sweep, per autotune rung, per engine construction): host-side
tallies whose cost is a dict lookup. Hot-path instrumentation (the serving
tick's per-tick histograms) is additionally gated on `trace.enabled()` so
the disabled serving path stays zero-cost -- see docs/observability.md for
the contract and `benchmarks/obs_overhead.py` for the gate.

`snapshot()` renders everything into ONE schema:

    {"counters":   {name: float},
     "gauges":     {name: float},
     "histograms": {name: {"count", "mean", "min", "max", "p50", "p99"}}}

and `stamp(doc)` embeds that snapshot under `doc["obs"]` -- every
`BENCH_*.json` artifact carries it, so benchmark JSONs finally share a
metrics schema instead of inventing per-module keys.

`percentile()` is the repo's ONE percentile implementation: EngineStats'
latency summaries (`serving/scheduler.py`) and the histogram summaries here
both call it, with the edge cases (empty -> None, singleton, duplicate
values) pinned by tests/test_obs.py.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

SNAPSHOT_SCHEMA_VERSION = 1


def percentile(values: Sequence[float], q: float) -> Optional[float]:
    """Percentile of `values` (None when empty -- 'no samples yet' must
    stay distinguishable from 0.0). Singleton lists return their element
    for every q; duplicate-value lists return that value."""
    if not len(values):
        return None
    return float(np.percentile(np.asarray(values, np.float64), q))


class Counter:
    """Monotone tally."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, value: float = 1.0) -> None:
        self.value += value


class Gauge:
    """Last-written value (queue depth, live lanes, current rung)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Value distribution summarized to count/mean/min/max/p50/p99."""

    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, value: float) -> None:
        self.values.append(float(value))

    def summary(self) -> Dict[str, Optional[float]]:
        v = self.values
        return {
            "count": len(v),
            "mean": float(np.mean(v)) if v else None,
            "min": float(min(v)) if v else None,
            "max": float(max(v)) if v else None,
            "p50": percentile(v, 50),
            "p99": percentile(v, 99),
        }


class MetricsRegistry:
    """Get-or-create registry of typed metrics. A name registered as one
    type cannot be re-registered as another (that is a bug, not a merge)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _get(self, store: Dict, name: str, cls):
        with self._lock:
            m = store.get(name)
            if m is None:
                for other in (self._counters, self._gauges,
                              self._histograms):
                    if other is not store and name in other:
                        raise ValueError(
                            f"metric {name!r} already registered as a "
                            f"different type")
                m = store[name] = cls(name)
            return m

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._histograms, name, Histogram)

    def snapshot(self) -> Dict:
        """The single snapshot schema every consumer reads/embeds."""
        with self._lock:
            return {
                "counters": {n: c.value
                             for n, c in sorted(self._counters.items())},
                "gauges": {n: g.value
                           for n, g in sorted(self._gauges.items())},
                "histograms": {n: h.summary()
                               for n, h in sorted(self._histograms.items())},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


_GLOBAL = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _GLOBAL


def snapshot() -> Dict:
    return _GLOBAL.snapshot()


def reset() -> None:
    _GLOBAL.reset()


def stamp(doc: Dict) -> Dict:
    """Return `doc` with the process metrics snapshot embedded under
    `doc["obs"]` -- the shared tail every BENCH_*.json artifact carries.
    (`benchmarks/run.py` resets the registry before each module, so a
    stamped artifact reflects that module's run.)"""
    out = dict(doc)
    out["obs"] = {"schema": SNAPSHOT_SCHEMA_VERSION, "metrics": snapshot()}
    return out
