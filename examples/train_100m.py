"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + resume.

Run:  PYTHONPATH=src:examples python examples/train_100m.py [--steps 200]

This exercises the full production path (config -> model -> sharded step ->
data -> optimizer -> checkpoint -> monitor) at a scale this CPU container
can execute; the same driver runs the full configs on a TPU mesh.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig
from repro.launch import train as train_mod
from repro.configs import registry


# ~100M params: 12L x d512 x ff2048, vocab 32k
CONFIG_100M = ModelConfig(
    name="repro-100m", family="dense", n_layers=12, d_model=512,
    n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=32000, head_dim=64,
    qk_norm=True, remat=False, compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # register the 100M config so the production trainer can resolve it
    registry._MODULES["repro-100m"] = __name__
    sys.modules[__name__].CONFIG = CONFIG_100M

    n = CONFIG_100M.param_count()
    print(f"training {CONFIG_100M.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq_len}")
    losses = train_mod.main([
        "--arch", "repro-100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq-len", str(args.seq_len),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "10",
    ])
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
