"""Blackscholes (PARSEC) under HPAC-Offload-style approximation.

The kernel prices European options analytically. GPU mapping (paper
section 3.1.3): each element ("thread") prices `steps` options over its
grid-stride iterations; option parameters follow a slow random walk, giving
the temporal output locality TAF exploits (the paper found BS data highly
redundant: up to 2.26x with 0.015% MAPE).

QoI: the computed prices (paper Table 1). Error: MAPE.
"""
from __future__ import annotations

import time
from functools import lru_cache

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ApproxSpec, Technique, batching
from repro.core.harness import AppResult, ApproxApp
from repro.core import iact as iact_mod
from repro.core import taf as taf_mod


def _phi(x):
    return 0.5 * (1.0 + jax.lax.erf(x / jnp.sqrt(2.0)))


def bs_price(inputs: jnp.ndarray) -> jnp.ndarray:
    """inputs: (N, 5) = [S, K, T, r, sigma] -> call prices (N,)."""
    s, k, t, r, sig = [inputs[:, i] for i in range(5)]
    d1 = (jnp.log(s / k) + (r + 0.5 * sig ** 2) * t) / (sig * jnp.sqrt(t))
    d2 = d1 - sig * jnp.sqrt(t)
    return s * _phi(d1) - k * jnp.exp(-r * t) * _phi(d2)


def gen_inputs(n_elements: int, steps: int, seed: int = 0,
               volatility: float = 1.0) -> np.ndarray:
    """(steps, n_elements, 5): random walk per element => temporal locality
    across an element's successive iterations. `volatility` scales the walk
    (regime-switching bursts appear above 1.0, making the RSD activation
    genuinely selective -- used by the Figure-10c experiment)."""
    rng = np.random.RandomState(seed)
    s0 = rng.uniform(20, 120, (n_elements,))
    k0 = s0 * rng.uniform(0.8, 1.2, (n_elements,))
    t0 = rng.uniform(0.2, 2.0, (n_elements,))
    r0 = np.full((n_elements,), 0.05)
    v0 = rng.uniform(0.1, 0.6, (n_elements,))
    base = np.stack([s0, k0, t0, r0, v0], axis=1)
    drift = rng.standard_normal((steps, n_elements, 5)) * \
        np.array([0.05, 0.0, 0.0, 0.0, 0.0005]) * min(volatility, 1.0)
    walk = base[None] * (1.0 + np.cumsum(drift, axis=0) * 0.01)
    if volatility > 1.0:
        # regime-switching: quiet stretches + occasional ~25% price jumps,
        # so window-RSD genuinely discriminates across thresholds
        jumps = (rng.uniform(size=(steps, n_elements)) < 0.10) * \
            rng.standard_normal((steps, n_elements)) * 0.25
        factor = np.exp(np.clip(np.cumsum(jumps, axis=0), -0.15, 0.35))
        walk[..., 0] *= factor
    return np.maximum(walk, 1e-3).astype(np.float32)


@lru_cache(maxsize=64)
def _jitted_runner(spec_key, n_elements, steps, seed, volatility=1.0):
    xs = jnp.asarray(gen_inputs(n_elements, steps, seed, volatility))
    spec = _SPECS[spec_key]

    if spec.technique == Technique.TAF:
        fn = jax.jit(lambda xs: taf_mod.run_sequence(
            spec.taf, xs, bs_price, spec.level))
    elif spec.technique == Technique.IACT:
        fn = jax.jit(lambda xs: iact_mod.run_sequence(
            spec.iact, xs, bs_price, spec.level))
    else:
        fn = jax.jit(lambda xs: (jax.vmap(bs_price)(xs), None,
                                 jnp.float32(0)))
    return fn, xs


_SPECS = {}


@lru_cache(maxsize=64)
def _group_runner(key, n_elements, steps, seed, volatility):
    """One compiled sweep over a STACK of traced scalars for a static-
    structure group (see core/batching.py): TAF groups vmap over RSD
    thresholds, iACT groups over distance thresholds; the structural params
    (history/prediction sizes, table shape, level) stay static."""
    xs = jnp.asarray(gen_inputs(n_elements, steps, seed, volatility))
    seq = batching.sequence_runner(key, xs, bs_price)
    return jax.jit(jax.vmap(seq)) if seq is not None else None


def make_app(n_elements: int = 512, steps: int = 64,
             seed: int = 0, volatility: float = 1.0) -> ApproxApp:
    def run(spec: ApproxSpec) -> AppResult:
        key = repr(spec)
        _SPECS[key] = spec
        fn, xs = _jitted_runner(key, n_elements, steps, seed, volatility)
        out = fn(xs)  # compile + warmup
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        ys, _, frac = fn(xs)
        jax.block_until_ready(ys)
        wall = time.perf_counter() - t0
        frac = float(frac) if frac is not None else 0.0
        return AppResult(qoi=np.asarray(ys), wall_time_s=wall,
                         approx_fraction=frac,
                         flop_fraction=max(1.0 - frac, 1e-3))

    # ApproxApp.run_batch: specs sharing static structure (TAF hSize/pSize,
    # iACT tSize/tPerBlock, level) evaluate in one vmapped call over their
    # stacked thresholds; batch wall time is amortized per spec.
    # QoI/error/approx_fraction match the serial path up to XLA fusion
    # differences (~1e-7 relative). Everything else runs serially.
    run_batch = batching.make_run_batch(
        run, lambda key: _group_runner(key, n_elements, steps, seed,
                                       volatility))

    return ApproxApp(name="blackscholes", run=run, error_metric="mape",
                     run_batch=run_batch,
                     workload=dict(n_elements=n_elements, steps=steps,
                                   seed=seed, volatility=volatility))
