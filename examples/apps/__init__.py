"""JAX analogues of the HPAC-Offload benchmark suite (paper Table 1).

Each app follows the harness `ApproxApp` protocol: run(spec) executes the
app with a given approximation spec and returns its QoI + timing + approx
statistics. The apps are sized to run single configs in O(seconds) on this
CPU container; the DSE harness sweeps paper-Table-2-style grids over them.

  blackscholes     -- PARSEC Blackscholes (analytic European options)
  binomial_options -- CUDA SDK binomial American options (tree scan)
  kmeans           -- Rodinia K-Means (MCR metric, convergence speedup)
  lavamd           -- Rodinia LavaMD-like particle forces in boxes
  minife_cg        -- MiniFE-like CG solver on a Poisson stencil
  approx_ffn       -- kernel-backed transformer block (the only app whose
                      approximated region runs on the Pallas kernel
                      substrate; host substrate = the ref.py oracles)
"""
from . import (approx_ffn, binomial_options, blackscholes, kmeans, lavamd,
               minife_cg)

__all__ = ["approx_ffn", "binomial_options", "blackscholes", "kmeans",
           "lavamd", "minife_cg"]
