"""approx_ffn: the first KERNEL-backed workload -- a tiny transformer block
whose approximated region runs on the Pallas kernel substrate.

Every other app in this suite emulates the paper's techniques at the host
level (`core/taf.py`, `core/iact.py`); their sweeps therefore never touch
`src/repro/kernels/`. This app closes that gap: the pipeline

    x --taf_matmul--> proj --perforated_attention--> ctx --iact_rowfn--> y

puts one Pallas kernel behind each technique, and the spec's technique
selects which stage is approximated (the others run exact):

  TAF          -- the (S, d) x (d, d) projection via `kernels.taf_matmul`
                  (block-level output memoization over row blocks);
  IACT         -- the FFN tile via `kernels.iact_rowfn` (VMEM memo table,
                  majority vote, single-writer insert);
  PERFORATION  -- self-attention via `kernels.perforated_attention` (herded
                  KV-block dropping; traced-fraction masked mode).

Substrates (`repro.core.substrate`):

  "pallas" -- the kernels (Mosaic on TPU, interpret mode on CPU). Quality
              knobs are TRACED kernel operands: a serial threshold sweep
              compiles once per structural group, and `run_batch` vmaps
              stacked knobs through one compiled pipeline per group.
  "host"   -- the pure-jnp/numpy oracles in `kernels/ref.py`, which
              implement identical block semantics: the parity reference
              for outputs, approx masks and QoI error.

Decisions are block-level on both substrates (the kernels' only
real-savings mode; specs should use Level.BLOCK). QoI: the block's output
activations. Error: MAPE. Wall times on CPU are interpret-mode (Python)
numbers -- meaningful only relatively; `flop_fraction` carries the
machine-true structural savings.
"""
from __future__ import annotations

import time
from functools import lru_cache
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import batching
from repro.core import perforation as perfo_mod
from repro.core import substrate as substrate_mod
from repro.core.harness import AppResult, ApproxApp
from repro.core.types import ApproxSpec, PerforationKind, Technique

# Block geometry: fixed by the app (structural; not part of the spec grid).
# `make_app(blocks=...)` overrides it -- blocks are SEMANTIC here (approx
# masks are block-granular), so a non-default geometry is a different
# workload fingerprint, recorded in the app's workload dict.
_BLOCK_M = 16      # taf_matmul row block => seq/16 temporal steps
_BLOCK_ROWS = 16   # iact_rowfn rows per table block
_BLOCK_ATTN = 32   # attention q/kv block => seq/32 KV blocks


def _blocks3(blocks):
    """(block_m, block_rows, block_attn) -- module defaults when None."""
    return (_BLOCK_M, _BLOCK_ROWS, _BLOCK_ATTN) if blocks is None \
        else tuple(blocks)


def tuned_blocks(seq: int = 128, d: int = 32, d_h: int = 64,
                 heads: int = 2) -> Tuple[int, int, int]:
    """The tuning-cache blocks for this app's kernel shapes (per-kernel
    exact-shape lookup through `kernels.tuning`), falling back to the
    module defaults on any miss. `make_app(blocks="tuned")` resolves
    through here."""
    from repro.kernels import tuning
    taf = tuning.tuned_config("taf_matmul", ((seq, d), (d, d))) or {}
    iact = tuning.tuned_config("iact_rowfn",
                               ((seq, d), (d, d_h), (d_h, d))) or {}
    attn_shape = (1, heads, seq, d // heads)
    attn = tuning.tuned_config("perforated_attention",
                               (attn_shape, attn_shape)) or {}
    return (int(taf.get("block_m", _BLOCK_M)),
            int(iact.get("block_rows", _BLOCK_ROWS)),
            int(attn.get("block_kv", attn.get("block_q", _BLOCK_ATTN))))


def gen_inputs(seq: int, d: int, seed: int = 0) -> np.ndarray:
    """(seq, d) with row-BLOCK temporal locality: rows within a 16-row block
    are near-identical and successive blocks drift on a slow random walk, so
    TAF's window RSD and iACT's distance threshold genuinely discriminate
    across the sweep grids."""
    rng = np.random.RandomState(seed)
    n_blocks = seq // _BLOCK_M
    base = rng.randn(1, d).astype(np.float32)
    drift = np.cumsum(0.04 * rng.randn(n_blocks, 1, d), axis=0)
    blocks = base[None] + drift.astype(np.float32)           # (B, 1, d)
    x = np.repeat(blocks, _BLOCK_M, axis=1).reshape(seq, d)
    x = x + 0.01 * rng.randn(seq, d).astype(np.float32)
    return x.astype(np.float32)


@lru_cache(maxsize=8)
def _arrays(seq: int, d: int, d_h: int, heads: int, seed: int):
    rng = np.random.RandomState(seed + 1)
    x = jnp.asarray(gen_inputs(seq, d, seed))
    wp = jnp.asarray(rng.randn(d, d).astype(np.float32) / np.sqrt(d))
    w1 = jnp.asarray(rng.randn(d, d_h).astype(np.float32) / np.sqrt(d))
    w2 = jnp.asarray(rng.randn(d_h, d).astype(np.float32) / np.sqrt(d_h))
    return x, wp, w1, w2


def _split_heads(p: jnp.ndarray, heads: int) -> jnp.ndarray:
    s, d = p.shape
    return p.reshape(s, heads, d // heads).transpose(1, 0, 2)[None]


def _merge_heads(a: jnp.ndarray) -> jnp.ndarray:
    _, h, s, dh = a.shape
    return a[0].transpose(1, 0, 2).reshape(s, h * dh)


def _attn_exact(p: jnp.ndarray, heads: int) -> jnp.ndarray:
    from repro.kernels import ref
    q = _split_heads(p, heads)
    return _merge_heads(ref.attention_ref(q, q, q, causal=True))


def _ffn_exact(a: jnp.ndarray, w1, w2) -> jnp.ndarray:
    return jax.nn.gelu(a @ w1) @ w2


def _flops(seq: int, d: int, d_h: int) -> Tuple[float, float, float]:
    """(proj, attn, ffn) accurate-path FLOPs (causal factor ignored: it is
    common to numerator and denominator of flop_fraction)."""
    proj = 2.0 * seq * d * d
    attn = 4.0 * seq * seq * d
    ffn = 2.0 * seq * d * d_h + 2.0 * seq * d_h * d
    return proj, attn, ffn


def _flop_fraction(technique: Technique, approx_frac, seq, d, d_h):
    proj, attn, ffn = _flops(seq, d, d_h)
    total = proj + attn + ffn
    if technique == Technique.TAF:
        exec_ = proj * (1.0 - approx_frac) + attn + ffn
    elif technique == Technique.IACT:
        exec_ = proj + attn + ffn * (1.0 - approx_frac)
    elif technique == Technique.PERFORATION:
        exec_ = proj + attn * (1.0 - approx_frac) + ffn
    else:
        exec_ = total
    return max(float(exec_ / total), 1e-3)


# ---------------------------------------------------------------------------
# Pallas substrate: jitted pipelines, one compile per STRUCTURAL group
# ---------------------------------------------------------------------------

@lru_cache(maxsize=8)
def _exact_runner(seq, d, d_h, heads, seed):
    x, wp, w1, w2 = _arrays(seq, d, d_h, heads, seed)

    @jax.jit
    def run():
        a = _attn_exact(x @ wp, heads)
        return _ffn_exact(a, w1, w2)
    return run


@lru_cache(maxsize=64)
def _pallas_knob_runner(key, seq, d, d_h, heads, seed, blocks=None):
    """jitted `fn(knob) -> (qoi, approx_frac, mask)` for a batching
    static-structure key: the quality knob is a TRACED argument, so every
    spec in the group -- and, under `jax.vmap`, a whole stack of them --
    shares this one compiled pipeline. `blocks` (an optional
    (block_m, block_rows, block_attn) tuple) is part of the lru key:
    default-geometry callers MUST omit it so they share one entry."""
    x, wp, w1, w2 = _arrays(seq, d, d_h, heads, seed)
    block_m, block_rows, block_attn = _blocks3(blocks)
    spec = batching.spec_from_key(key)
    tech = key[0]

    if tech == Technique.TAF:
        def body(knob):
            p, mask = substrate_mod.taf_matmul_region(
                x, wp, spec, block_m=block_m, block_n=d, rsd_threshold=knob)
            qoi = _ffn_exact(_attn_exact(p, heads), w1, w2)
            frac = jnp.mean(mask.astype(jnp.float32))
            return qoi, frac, mask
    elif tech == Technique.IACT:
        def body(knob):
            a = _attn_exact(x @ wp, heads)
            qoi, mask = substrate_mod.iact_ffn_region(
                a, w1, w2, spec, block_rows=block_rows, threshold=knob)
            frac = jnp.mean(mask.astype(jnp.float32))
            return qoi, frac, mask
    elif tech == Technique.PERFORATION:
        def body(knob):
            p = x @ wp
            q = _split_heads(p, heads)
            o, kept = substrate_mod.attention_region(
                q, q, q, spec, block_q=block_attn, block_kv=block_attn,
                fraction=knob)
            qoi = _ffn_exact(_merge_heads(o), w1, w2)
            frac = 1.0 - jnp.mean(kept.astype(jnp.float32))
            return qoi, frac, jnp.logical_not(kept)
    else:
        raise ValueError(f"no knob runner for {tech}")
    return jax.jit(body)


@lru_cache(maxsize=64)
def _pallas_structural_runner(perfo, seq, d, d_h, heads, seed, blocks=None):
    """Structural (skip-driven) perforation: the kept set shapes the grid,
    so each distinct `perfo` is its own compile -- the herded payoff is that
    dropped KV blocks are never visited at all."""
    x, wp, w1, w2 = _arrays(seq, d, d_h, heads, seed)
    block_attn = _blocks3(blocks)[2]
    spec = ApproxSpec(Technique.PERFORATION, perforation=perfo)

    @jax.jit
    def run():
        p = x @ wp
        q = _split_heads(p, heads)
        o, kept = substrate_mod.attention_region(
            q, q, q, spec, block_q=block_attn, block_kv=block_attn)
        qoi = _ffn_exact(_merge_heads(o), w1, w2)
        frac = 1.0 - jnp.mean(kept.astype(jnp.float32))
        return qoi, frac, jnp.logical_not(kept)
    return run


# ---------------------------------------------------------------------------
# Host substrate: the ref.py oracles (identical block semantics, eager)
# ---------------------------------------------------------------------------

def _host_eval(spec: ApproxSpec, seq, d, d_h, heads, seed, blocks=None):
    from repro.kernels import ref
    x, wp, w1, w2 = _arrays(seq, d, d_h, heads, seed)
    block_m, block_rows, block_attn = _blocks3(blocks)
    t = spec.technique
    if t == Technique.TAF:
        p, mask = ref.taf_matmul_ref(
            x, wp, block_m=block_m, block_n=d,
            history_size=spec.taf.history_size,
            prediction_size=spec.taf.prediction_size,
            rsd_threshold=spec.taf.rsd_threshold)
        qoi = _ffn_exact(_attn_exact(p, heads), w1, w2)
        return qoi, np.asarray(mask)
    if t == Technique.IACT:
        a = _attn_exact(x @ wp, heads)
        qoi, mask = ref.iact_rowfn_ref(
            a, w1, w2, block_rows=block_rows,
            table_size=spec.iact.table_size,
            threshold=spec.iact.threshold)
        return qoi, np.asarray(mask)
    if t == Technique.PERFORATION:
        p = x @ wp
        q = _split_heads(p, heads)
        o = ref.attention_ref(q, q, q, causal=True, block_kv=block_attn,
                              perfo=spec.perforation)
        qoi = _ffn_exact(_merge_heads(o), w1, w2)
        nkv = seq // block_attn
        mask = ~perfo_mod.execute_mask(nkv, spec.perforation)
        return qoi, mask
    raise ValueError(f"no host evaluator for {t}")  # NONE handled by run()


# ---------------------------------------------------------------------------
# The ApproxApp
# ---------------------------------------------------------------------------

def make_app(substrate: Optional[str] = None, seq: int = 128, d: int = 32,
             d_h: int = 64, heads: int = 2, seed: int = 0,
             blocks=None) -> ApproxApp:
    """`substrate=None` resolves the ambient default ONCE, at construction
    (it is part of the workload fingerprint: pallas and host rows must not
    share DB cache keys).

    `blocks`: None (module default geometry, back-compatible fingerprint),
    an explicit (block_m, block_rows, block_attn) tuple, or "tuned" (the
    tuning-cache winners for this geometry via `tuned_blocks`). Non-default
    blocks change the approx masks' granularity, so they join the workload
    fingerprint -- rows swept at different geometries never share DB keys.
    """
    sub = substrate_mod.resolve(substrate)
    if blocks == "tuned":
        blocks = tuned_blocks(seq, d, d_h, heads)
    if blocks is not None:
        blocks = tuple(int(b) for b in blocks)
        if blocks == _blocks3(None):
            blocks = None  # identical geometry: keep the default fingerprint
    block_m, block_rows, block_attn = _blocks3(blocks)
    if seq % block_m or seq % block_rows or seq % block_attn:
        raise ValueError(
            f"approx_ffn blocks (block_m={block_m}, block_rows={block_rows},"
            f" block_attn={block_attn}) must divide seq={seq}")
    assert seq % block_attn == 0 and d % heads == 0

    def _knob_runner(key):
        if blocks is None:  # positional-default call: shares the lru entry
            return _pallas_knob_runner(key, seq, d, d_h, heads, seed)
        return _pallas_knob_runner(key, seq, d, d_h, heads, seed, blocks)

    def _structural_runner(perfo):
        if blocks is None:
            return _pallas_structural_runner(perfo, seq, d, d_h, heads, seed)
        return _pallas_structural_runner(perfo, seq, d, d_h, heads, seed,
                                         blocks)

    def _result(spec, qoi, frac, mask, wall):
        return AppResult(
            qoi=np.asarray(qoi), wall_time_s=wall,
            approx_fraction=float(frac),
            flop_fraction=_flop_fraction(spec.technique, float(frac),
                                         seq, d, d_h),
            extra={"approx_mask":
                   np.asarray(mask).astype(int).ravel().tolist()})

    def run(spec: ApproxSpec) -> AppResult:
        # The exact baseline shares one jitted pipeline across substrates;
        # warm it up so the compile never lands inside the timed window
        # (Record.speedup divides by this wall time).
        if spec.technique == Technique.NONE:
            fn = _exact_runner(seq, d, d_h, heads, seed)
            jax.block_until_ready(fn())  # compile + warmup
            t0 = time.perf_counter()
            qoi = jax.block_until_ready(fn())
            return _result(spec, qoi, 0.0, np.zeros((0,)),
                           time.perf_counter() - t0)
        if sub == substrate_mod.HOST:
            # eager oracle loops: no compile to warm, but the exact stages
            # they share (_attn_exact/_ffn_exact) are jnp -- run once so
            # dispatch setup is off the clock too
            _host_eval(spec, seq, d, d_h, heads, seed, blocks)
            t0 = time.perf_counter()
            qoi, mask = _host_eval(spec, seq, d, d_h, heads, seed, blocks)
            qoi = jax.block_until_ready(qoi)
            wall = time.perf_counter() - t0
            frac = float(mask.mean()) if mask.size else 0.0
            return _result(spec, qoi, frac, mask, wall)
        # pallas substrate: pick the structurally-right compiled runner
        key = batching.static_key(spec)
        if key is not None:
            fn = _knob_runner(key)
            knob = jnp.float32(batching.traced_param(spec))
            out = fn(knob)  # compile (per structural group) + warmup
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            qoi, frac, mask = fn(knob)
            jax.block_until_ready(qoi)
        else:  # skip-driven perforation: structural kept set
            fn = _structural_runner(spec.perforation)
            jax.block_until_ready(fn())
            t0 = time.perf_counter()
            qoi, frac, mask = fn()
            jax.block_until_ready(qoi)
        return _result(spec, qoi, float(frac), mask,
                       time.perf_counter() - t0)

    run_batch = None
    if sub == substrate_mod.PALLAS:
        def make_group_fn(key):
            knob_fn = _knob_runner(key)
            vmapped = jax.jit(jax.vmap(knob_fn))

            def group(knobs):
                qois, fracs, masks = vmapped(knobs)
                return qois, fracs, {"approx_mask": masks}
            return group

        def result_builder(qoi, frac, extra, wall, spec):
            mask = np.asarray(extra.get("approx_mask", np.zeros((0,))))
            return _result(spec, qoi, frac, mask, wall)

        run_batch = batching.make_run_batch(run, make_group_fn,
                                            result_builder=result_builder)

    workload = dict(substrate=sub, seq=seq, d=d, d_h=d_h, heads=heads,
                    seed=seed)
    if blocks is not None:
        # tuned/explicit geometry changes mask granularity: new fingerprint
        workload["blocks"] = list(blocks)
    return ApproxApp(
        name="approx_ffn", run=run, error_metric="mape",
        run_batch=run_batch, workload=workload)
