"""K-Means (Rodinia) under approximation.

The approximated region is the per-iteration distance/assignment kernel.
QoI: final cluster id per observation; error metric: MCR (paper Eq. 2).
The paper's key finding (Figure 12c): approximation herds observations into
stable clusters => EARLY CONVERGENCE; speedup correlates with convergence
speedup (R^2 = 0.95). This app therefore reports iterations-to-converge for
the exact and approximate runs in `extra`.

Batched runner: the serial path is a host loop that breaks on convergence,
which a vmapped evaluation cannot do -- lanes converge at different
iterations. `_converging_scan` runs the same per-iteration step under
``lax.scan`` with a frozen carry: once a lane's assignment repeats, its
centers/state/assignment stop updating and its iteration count is pinned,
reproducing the host loop's break semantics exactly (same assignments, same
iters, same mean approx fraction).
"""
from __future__ import annotations

import time
from functools import lru_cache

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ApproxSpec, Technique, batching
from repro.core.harness import AppResult, ApproxApp
from repro.core import iact as iact_mod
from repro.core import taf as taf_mod


def gen_data(n: int = 2048, d: int = 8, k: int = 12, seed: int = 0):
    rng = np.random.RandomState(seed)
    centers = rng.standard_normal((k, d)) * 4.0
    assign = rng.randint(0, k, n)
    pts = centers[assign] + rng.standard_normal((n, d))
    return pts.astype(np.float32), k


def _assign_exact(pts, centers):
    d2 = jnp.sum((pts[:, None, :] - centers[None]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=1)


def _init_state(technique, params, n, d):
    if technique == Technique.TAF:
        return taf_mod.init(params, n, (), jnp.float32)
    if technique == Technique.IACT:
        n_tab = iact_mod.n_tables_for(params, n)
        return iact_mod.init(params, n_tab, d, (), jnp.float32)
    return None


def _make_step(pts_j, k, technique, params, level):
    """One Lloyd iteration: approximated assignment + centroid update.

    The returned step(centers, state, th) takes the technique's traced
    scalar `th` (None = use params' static value) -- shared by the serial
    host loop and the vmapped batched runner.
    """
    n = pts_j.shape[0]

    def step(centers, state, th=None):
        if technique == Technique.TAF:
            out, new_state, mask = taf_mod.step(
                state, lambda: _assign_exact(pts_j, centers).astype(
                    jnp.float32), params, level, rsd_threshold=th)
            assign = out.astype(jnp.int32)
        elif technique == Technique.IACT:
            out, new_state, mask = iact_mod.step(
                state, pts_j,
                lambda x: _assign_exact(x, centers).astype(jnp.float32),
                params, level, threshold=th)
            assign = out.astype(jnp.int32)
        else:
            assign = _assign_exact(pts_j, centers)
            new_state, mask = state, jnp.zeros((n,), bool)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
        counts = jnp.maximum(onehot.sum(0), 1.0)
        new_centers = (onehot.T @ pts_j) / counts[:, None]
        return new_centers, assign, new_state, jnp.mean(
            mask.astype(jnp.float32))

    return step


def _spec_params(spec: ApproxSpec):
    if spec.technique == Technique.TAF:
        return spec.taf
    if spec.technique == Technique.IACT:
        return spec.iact
    return None


def _init_centers(pts, k):
    rng = np.random.RandomState(1)
    return jnp.asarray(pts[rng.choice(pts.shape[0], k, replace=False)])


def run_kmeans(pts: np.ndarray, k: int, spec: ApproxSpec,
               max_iters: int = 40):
    """Lloyd's algorithm; the distance kernel output (min-distance centroid
    index summary) is the approximated region, per element (observation)."""
    n, dim = pts.shape
    pts_j = jnp.asarray(pts)
    params = _spec_params(spec)
    state = _init_state(spec.technique, params, n, dim)
    step = jax.jit(_make_step(pts_j, k, spec.technique, params, spec.level))

    centers = _init_centers(pts, k)
    prev = None
    fracs = []
    iters = max_iters
    for it in range(max_iters):
        centers, assign, state, frac = step(centers, state)
        fracs.append(float(frac))
        a = np.asarray(assign)
        if prev is not None and np.array_equal(a, prev):
            iters = it + 1
            break
        prev = a
    return prev if prev is not None else np.asarray(assign), iters, \
        float(np.mean(fracs))


def _converging_scan(step, centers0, state0, n, max_iters):
    """The host convergence loop as a scan with a frozen carry.

    Returns a traced fn(th) -> (final_assign, mean_frac, {'iters': iters})
    whose results match run_kmeans' break semantics lane-for-lane.
    """
    def one(th):
        carry0 = (centers0, state0,
                  jnp.zeros((n,), jnp.int32),    # prev assignment
                  jnp.bool_(False),              # has_prev
                  jnp.bool_(False),              # done (converged)
                  jnp.int32(max_iters),          # iterations executed
                  jnp.float32(0.0), jnp.int32(0))  # frac sum / count

        def body(carry, t):
            centers, state, prev, has_prev, done, iters, fsum, nexec = carry
            new_centers, assign, new_state, frac = step(centers, state, th)
            conv = has_prev & jnp.all(assign == prev)
            take = ~done
            freeze = lambda new, old: jnp.where(done, old, new)
            centers = freeze(new_centers, centers)
            state = jax.tree.map(freeze, new_state, state)
            prev = jnp.where(done, prev, assign)
            iters = jnp.where(take & conv, t + 1, iters)
            fsum = fsum + jnp.where(take, frac, 0.0)
            nexec = nexec + jnp.where(take, 1, 0)
            return (centers, state, prev, has_prev | take, done | conv,
                    iters, fsum, nexec), None

        carry, _ = jax.lax.scan(body, carry0, jnp.arange(max_iters))
        _, _, prev, _, _, iters, fsum, nexec = carry
        frac = fsum / jnp.maximum(nexec, 1).astype(jnp.float32)
        return prev, frac, {"iters": iters}

    return one


@lru_cache(maxsize=64)
def _group_runner(key, n, d, k, seed, max_iters):
    """Batched-runner group evaluation (core/batching.py): vmap the whole
    converging Lloyd loop over a stack of thresholds."""
    pts, k = gen_data(n, d, k, seed)
    tech, level = key[0], key[1]
    if tech not in (Technique.TAF, Technique.IACT):
        return None
    params = batching.params_from_key(key)
    pts_j = jnp.asarray(pts)
    step = _make_step(pts_j, k, tech, params, level)
    state0 = _init_state(tech, params, n, d)
    one = _converging_scan(step, _init_centers(pts, k), state0, n, max_iters)
    return jax.jit(jax.vmap(one))


def make_app(n: int = 2048, d: int = 8, k: int = 12,
             seed: int = 0, max_iters: int = 40) -> ApproxApp:
    pts, k = gen_data(n, d, k, seed)

    def _result(qoi, frac, iters, wall):
        return AppResult(qoi=qoi, wall_time_s=wall, approx_fraction=frac,
                         flop_fraction=max(iters / max_iters * (1 - frac),
                                           1e-3),
                         extra={"iters": iters})

    def run(spec: ApproxSpec) -> AppResult:
        t0 = time.perf_counter()
        assign, iters, frac = run_kmeans(pts, k, spec, max_iters)
        wall = time.perf_counter() - t0
        return _result(assign, frac, iters, wall)

    run_batch = batching.make_run_batch(
        run, lambda key: _group_runner(key, n, d, k, seed, max_iters),
        result_builder=lambda qoi, frac, extra, wall: _result(
            qoi, frac, int(extra.get("iters", max_iters)), wall))

    return ApproxApp(name="kmeans", run=run, error_metric="mcr",
                     run_batch=run_batch,
                     workload=dict(n=n, d=d, k=k, seed=seed,
                                   max_iters=max_iters))
