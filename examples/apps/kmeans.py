"""K-Means (Rodinia) under approximation.

The approximated region is the per-iteration distance/assignment kernel.
QoI: final cluster id per observation; error metric: MCR (paper Eq. 2).
The paper's key finding (Figure 12c): approximation herds observations into
stable clusters => EARLY CONVERGENCE; speedup correlates with convergence
speedup (R^2 = 0.95). This app therefore reports iterations-to-converge for
the exact and approximate runs in `extra`.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ApproxSpec, Technique
from repro.core.harness import AppResult, ApproxApp
from repro.core import iact as iact_mod
from repro.core import taf as taf_mod


def gen_data(n: int = 2048, d: int = 8, k: int = 12, seed: int = 0):
    rng = np.random.RandomState(seed)
    centers = rng.standard_normal((k, d)) * 4.0
    assign = rng.randint(0, k, n)
    pts = centers[assign] + rng.standard_normal((n, d))
    return pts.astype(np.float32), k


def _assign_exact(pts, centers):
    d2 = jnp.sum((pts[:, None, :] - centers[None]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=1)


def run_kmeans(pts: np.ndarray, k: int, spec: ApproxSpec,
               max_iters: int = 40):
    """Lloyd's algorithm; the distance kernel output (min-distance centroid
    index summary) is the approximated region, per element (observation)."""
    n, dim = pts.shape
    pts_j = jnp.asarray(pts)

    state = None
    if spec.technique == Technique.TAF:
        state = taf_mod.init(spec.taf, n, (), jnp.float32)
    elif spec.technique == Technique.IACT:
        n_tab = iact_mod.n_tables_for(spec.iact, n)
        state = iact_mod.init(spec.iact, n_tab, dim, (), jnp.float32)

    @jax.jit
    def step(centers, state):
        if spec.technique == Technique.TAF:
            out, new_state, mask = taf_mod.step(
                state, lambda: _assign_exact(pts_j, centers).astype(
                    jnp.float32), spec.taf, spec.level)
            assign = out.astype(jnp.int32)
        elif spec.technique == Technique.IACT:
            out, new_state, mask = iact_mod.step(
                state, pts_j,
                lambda x: _assign_exact(x, centers).astype(jnp.float32),
                spec.iact, spec.level)
            assign = out.astype(jnp.int32)
        else:
            assign = _assign_exact(pts_j, centers)
            new_state, mask = state, jnp.zeros((n,), bool)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
        counts = jnp.maximum(onehot.sum(0), 1.0)
        new_centers = (onehot.T @ pts_j) / counts[:, None]
        return new_centers, assign, new_state, jnp.mean(
            mask.astype(jnp.float32))

    rng = np.random.RandomState(1)
    centers = jnp.asarray(pts[rng.choice(n, k, replace=False)])
    prev = None
    fracs = []
    iters = max_iters
    for it in range(max_iters):
        centers, assign, state, frac = step(centers, state)
        fracs.append(float(frac))
        a = np.asarray(assign)
        if prev is not None and np.array_equal(a, prev):
            iters = it + 1
            break
        prev = a
    return prev if prev is not None else np.asarray(assign), iters, \
        float(np.mean(fracs))


def make_app(n: int = 2048, d: int = 8, k: int = 12,
             seed: int = 0) -> ApproxApp:
    pts, k = gen_data(n, d, k, seed)

    def run(spec: ApproxSpec) -> AppResult:
        t0 = time.perf_counter()
        assign, iters, frac = run_kmeans(pts, k, spec)
        wall = time.perf_counter() - t0
        return AppResult(qoi=assign, wall_time_s=wall, approx_fraction=frac,
                         flop_fraction=max(iters / 40 * (1 - frac), 1e-3),
                         extra={"iters": iters})

    return ApproxApp(name="kmeans", run=run, error_metric="mcr",
                     workload=dict(n=n, d=d, k=k, seed=seed))
