"""Binomial American option pricing (paper: CUDA SDK BinomialOptions).

Each option price is an O(tree_steps^2) backward induction -- the paper's
"entire block collaboratively computes the price of a single option", hence
block-level decision-making only. The expensive region is the whole tree;
TAF/iACT memoize across an element's successive options.

This app also powers the Figure-8c experiment: with a fixed workload of
n_total options, `items_per_thread` trades element parallelism against
per-element approximation potential.
"""
from __future__ import annotations

import time
from functools import lru_cache

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ApproxSpec, Technique, batching
from repro.core.harness import AppResult, ApproxApp
from repro.core import iact as iact_mod
from repro.core import taf as taf_mod


def binomial_price(inputs: jnp.ndarray, tree_steps: int = 128) -> jnp.ndarray:
    """inputs: (N, 5) = [S, K, T, r, sigma] -> American put prices (N,)."""
    s, k, t, r, sig = [inputs[:, i] for i in range(5)]
    dt = t / tree_steps
    u = jnp.exp(sig * jnp.sqrt(dt))
    d = 1.0 / u
    disc = jnp.exp(-r * dt)
    p = (jnp.exp(r * dt) - d) / (u - d)
    j = jnp.arange(tree_steps + 1, dtype=jnp.float32)
    # terminal prices: (N, steps+1)
    st = s[:, None] * u[:, None] ** (2.0 * j[None, :] - tree_steps)
    vals = jnp.maximum(k[:, None] - st, 0.0)

    def backstep(i, vals):
        cont = disc[:, None] * (p[:, None] * vals[:, 1:] +
                                (1 - p[:, None]) * vals[:, :-1])
        level = tree_steps - i - 1
        stl = s[:, None] * u[:, None] ** (
            2.0 * j[None, :-1] - level)
        ex = jnp.maximum(k[:, None] - stl, 0.0)
        new = jnp.maximum(cont, ex)
        return jnp.pad(new, ((0, 0), (0, 1)))

    vals = jax.lax.fori_loop(0, tree_steps, backstep, vals)
    return vals[:, 0]


def gen_inputs(n_elements: int, steps: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    s0 = rng.uniform(20, 120, (n_elements,))
    base = np.stack([
        s0, s0 * rng.uniform(0.9, 1.1, (n_elements,)),
        rng.uniform(0.2, 2.0, (n_elements,)),
        np.full((n_elements,), 0.05),
        rng.uniform(0.1, 0.6, (n_elements,)),
    ], axis=1)
    drift = rng.standard_normal((steps, n_elements, 5)) * \
        np.array([0.03, 0.0, 0.0, 0.0, 0.0003])
    walk = base[None] * (1.0 + np.cumsum(drift, axis=0) * 0.01)
    return np.maximum(walk, 1e-3).astype(np.float32)


_SPECS = {}


@lru_cache(maxsize=64)
def _jitted_runner(spec_key, n_elements, steps, tree_steps, seed):
    xs = jnp.asarray(gen_inputs(n_elements, steps, seed))
    spec = _SPECS[spec_key]
    fn_price = lambda x: binomial_price(x, tree_steps)

    if spec.technique == Technique.TAF:
        fn = jax.jit(lambda xs: taf_mod.run_sequence(
            spec.taf, xs, fn_price, spec.level))
    elif spec.technique == Technique.IACT:
        fn = jax.jit(lambda xs: iact_mod.run_sequence(
            spec.iact, xs, fn_price, spec.level))
    else:
        fn = jax.jit(lambda xs: (jax.lax.map(fn_price, xs), None,
                                 jnp.float32(0)))
    return fn, xs


@lru_cache(maxsize=64)
def _group_runner(key, n_elements, steps, tree_steps, seed):
    """Batched-runner group evaluation (core/batching.py): one jitted vmap
    over the group's stacked thresholds; the tree and table shapes are
    static."""
    xs = jnp.asarray(gen_inputs(n_elements, steps, seed))
    seq = batching.sequence_runner(key, xs,
                                   lambda x: binomial_price(x, tree_steps))
    return jax.jit(jax.vmap(seq)) if seq is not None else None


def make_app(n_elements: int = 64, steps: int = 32, tree_steps: int = 128,
             seed: int = 0) -> ApproxApp:
    def run(spec: ApproxSpec) -> AppResult:
        key = repr(spec)
        _SPECS[key] = spec
        fn, xs = _jitted_runner(key, n_elements, steps, tree_steps, seed)
        out = fn(xs)
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        ys, _, frac = fn(xs)
        jax.block_until_ready(ys)
        wall = time.perf_counter() - t0
        frac = float(frac) if frac is not None else 0.0
        return AppResult(qoi=np.asarray(ys), wall_time_s=wall,
                         approx_fraction=frac,
                         flop_fraction=max(1.0 - frac, 1e-3))

    run_batch = batching.make_run_batch(
        run, lambda key: _group_runner(key, n_elements, steps, tree_steps,
                                       seed))

    return ApproxApp(name="binomial_options", run=run, error_metric="mape",
                     run_batch=run_batch,
                     workload=dict(n_elements=n_elements, steps=steps,
                                   tree_steps=tree_steps, seed=seed))
