"""MiniFE-like implicit finite-element solve: CG on a 2-D Poisson stencil.

The approximated region is the sparse matvec inside CG. The paper found
MiniFE hostile to AC: "locally introduced errors propagate through
subsequent iterations, causing high error rates (between 593% and 3.4e22%)"
and iACT inapplicable (non-uniform input sizes). This app reproduces that
qualitative blow-up: perforating or TAF-memoizing the matvec corrupts the
Krylov subspace and the residual diverges. QoI: final solution vector
(equivalently the residual norm, in `extra`).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ApproxSpec, Technique
from repro.core.harness import AppResult, ApproxApp
from repro.core.perforation import execute_mask
from repro.core import taf as taf_mod


def poisson_matvec(x2d: jnp.ndarray) -> jnp.ndarray:
    """5-point stencil matvec on an (n, n) grid with Dirichlet boundary."""
    out = 4.0 * x2d
    out = out - jnp.pad(x2d[1:, :], ((0, 1), (0, 0)))
    out = out - jnp.pad(x2d[:-1, :], ((1, 0), (0, 0)))
    out = out - jnp.pad(x2d[:, 1:], ((0, 0), (0, 1)))
    out = out - jnp.pad(x2d[:, :-1], ((0, 0), (1, 0)))
    return out


def cg_solve(b2d: jnp.ndarray, spec: ApproxSpec, iters: int = 60):
    """CG with an (optionally approximated) matvec. Row-block TAF: each of
    the grid's row-blocks is an element; a stable row-block's matvec output
    is memoized (exactly the paper's function-output memoization applied to
    the sparse matvec)."""
    n = b2d.shape[0]
    nblocks = 8
    rows = n // nblocks

    taf_state = None
    if spec.technique == Technique.TAF:
        taf_state = taf_mod.init(spec.taf, nblocks, (rows, n), jnp.float32)

    perfo_mask = None
    if spec.technique == Technique.PERFORATION:
        perfo_mask = jnp.asarray(
            np.repeat(execute_mask(nblocks, spec.perforation), rows)
        )[:, None]

    def matvec(x2d, state):
        if spec.technique == Technique.TAF:
            def accurate():
                return poisson_matvec(x2d).reshape(nblocks, rows, n)
            out, new_state, mask = taf_mod.step(state, accurate, spec.taf,
                                                spec.level)
            return out.reshape(n, n), new_state, jnp.mean(
                mask.astype(jnp.float32))
        y = poisson_matvec(x2d)
        if perfo_mask is not None:
            y = jnp.where(perfo_mask, y, 0.0)  # dropped rows contribute 0
            return y, state, jnp.float32(1.0 - perfo_mask.mean())
        return y, state, jnp.float32(0)

    x = jnp.zeros_like(b2d)
    r = b2d - 0.0
    p = r
    rs = jnp.sum(r * r)
    fracs = []
    state = taf_state
    for _ in range(iters):
        ap, state, frac = matvec(p, state)
        fracs.append(frac)
        alpha = rs / jnp.maximum(jnp.sum(p * ap), 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.sum(r * r)
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        rs = rs_new
    return x, jnp.sqrt(rs), float(np.mean([float(f) for f in fracs]))


def make_app(n: int = 64, seed: int = 0) -> ApproxApp:
    rng = np.random.RandomState(seed)
    b = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))

    def run(spec: ApproxSpec) -> AppResult:
        t0 = time.perf_counter()
        x, res, frac = jax.block_until_ready(
            cg_solve(b, spec)[0]), None, None
        # re-run to fetch residual/frac (cheap; sizes are small)
        x2, res, frac = cg_solve(b, spec)
        wall = time.perf_counter() - t0
        return AppResult(qoi=np.asarray(x2), wall_time_s=wall,
                         approx_fraction=frac,
                         flop_fraction=max(1.0 - frac, 1e-3),
                         extra={"residual": float(res)})

    return ApproxApp(name="minife_cg", run=run, error_metric="mape",
                     workload=dict(n=n, seed=seed))
