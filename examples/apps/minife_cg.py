"""MiniFE-like implicit finite-element solve: CG on a 2-D Poisson stencil.

The approximated region is the sparse matvec inside CG. The paper found
MiniFE hostile to AC: "locally introduced errors propagate through
subsequent iterations, causing high error rates (between 593% and 3.4e22%)"
and iACT inapplicable (non-uniform input sizes). This app reproduces that
qualitative blow-up: perforating or TAF-memoizing the matvec corrupts the
Krylov subspace and the residual diverges. QoI: final solution vector
(equivalently the residual norm, in `extra`).

The CG loop has a fixed trip count, so the whole solve is traceable: the
batched runner vmaps it over a stack of traced scalars -- the TAF RSD
threshold, or the perforation fraction (ini/fini/random kinds, whose
execute-mask is computed in-trace via `perforation.traced_execute_mask`).
"""
from __future__ import annotations

import time
from functools import lru_cache

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ApproxSpec, Technique, batching
from repro.core.harness import AppResult, ApproxApp
from repro.core.perforation import execute_mask, traced_execute_mask
from repro.core import taf as taf_mod

NBLOCKS = 8  # row-blocks of the grid = TAF elements


def poisson_matvec(x2d: jnp.ndarray) -> jnp.ndarray:
    """5-point stencil matvec on an (n, n) grid with Dirichlet boundary."""
    out = 4.0 * x2d
    out = out - jnp.pad(x2d[1:, :], ((0, 1), (0, 0)))
    out = out - jnp.pad(x2d[:-1, :], ((1, 0), (0, 0)))
    out = out - jnp.pad(x2d[:, 1:], ((0, 0), (0, 1)))
    out = out - jnp.pad(x2d[:, :-1], ((0, 0), (1, 0)))
    return out


def cg_solve(b2d: jnp.ndarray, spec: ApproxSpec, iters: int = 60,
             rsd_threshold=None, fraction=None):
    """CG with an (optionally approximated) matvec. Row-block TAF: each of
    the grid's row-blocks is an element; a stable row-block's matvec output
    is memoized (exactly the paper's function-output memoization applied to
    the sparse matvec).

    `rsd_threshold` (TAF) / `fraction` (ini/fini/random perforation) are the
    traced-parameter hooks: possibly traced scalars overriding the spec's
    static value, making the whole solve vmappable over a parameter stack.
    Returns (x, residual_norm, mean_approx_fraction) -- traced values.
    """
    n = b2d.shape[0]
    rows = n // NBLOCKS

    taf_state = None
    if spec.technique == Technique.TAF:
        taf_state = taf_mod.init(spec.taf, NBLOCKS, (rows, n), jnp.float32)

    perfo_mask = None
    if spec.technique == Technique.PERFORATION:
        if fraction is not None:
            block_mask = traced_execute_mask(NBLOCKS, spec.perforation,
                                             fraction)
        else:
            block_mask = jnp.asarray(execute_mask(NBLOCKS, spec.perforation))
        perfo_mask = jnp.repeat(block_mask, rows)[:, None]

    def matvec(x2d, state):
        if spec.technique == Technique.TAF:
            def accurate():
                return poisson_matvec(x2d).reshape(NBLOCKS, rows, n)
            out, new_state, mask = taf_mod.step(state, accurate, spec.taf,
                                                spec.level,
                                                rsd_threshold=rsd_threshold)
            return out.reshape(n, n), new_state, jnp.mean(
                mask.astype(jnp.float32))
        y = poisson_matvec(x2d)
        if perfo_mask is not None:
            y = jnp.where(perfo_mask, y, 0.0)  # dropped rows contribute 0
            return y, state, 1.0 - jnp.mean(perfo_mask.astype(jnp.float32))
        return y, state, jnp.float32(0)

    x = jnp.zeros_like(b2d)
    r = b2d - 0.0
    p = r
    rs = jnp.sum(r * r)
    fracs = []
    state = taf_state
    for _ in range(iters):
        ap, state, frac = matvec(p, state)
        fracs.append(frac)
        alpha = rs / jnp.maximum(jnp.sum(p * ap), 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.sum(r * r)
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        rs = rs_new
    return x, jnp.sqrt(rs), jnp.mean(jnp.stack(fracs))


def _gen_b(n: int, seed: int) -> jnp.ndarray:
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))


@lru_cache(maxsize=64)
def _group_runner(key, n, seed, iters):
    """Batched-runner group evaluation (core/batching.py): vmap the whole
    CG solve over a stack of RSD thresholds (TAF) or drop fractions
    (fraction-kind perforation)."""
    b = _gen_b(n, seed)
    tech = key[0]
    if tech == Technique.TAF:
        spec = batching.spec_from_key(key)
        one = lambda th: cg_solve(b, spec, iters, rsd_threshold=th)
    elif tech == Technique.PERFORATION:
        spec = batching.spec_from_key(key)
        one = lambda fr: cg_solve(b, spec, iters, fraction=fr)
    else:
        return None

    def run_one(scalar):
        x, res, frac = one(scalar)
        return x, frac, {"residual": res}

    return jax.jit(jax.vmap(run_one))


def make_app(n: int = 64, seed: int = 0, iters: int = 60) -> ApproxApp:
    b = _gen_b(n, seed)

    def run(spec: ApproxSpec) -> AppResult:
        t0 = time.perf_counter()
        x, res, frac = cg_solve(b, spec, iters)
        jax.block_until_ready(x)
        wall = time.perf_counter() - t0
        frac = float(frac)
        return AppResult(qoi=np.asarray(x), wall_time_s=wall,
                         approx_fraction=frac,
                         flop_fraction=max(1.0 - frac, 1e-3),
                         extra={"residual": float(res)})

    run_batch = batching.make_run_batch(
        run, lambda key: _group_runner(key, n, seed, iters))

    return ApproxApp(name="minife_cg", run=run, error_metric="mape",
                     run_batch=run_batch,
                     workload=dict(n=n, seed=seed, iters=iters))
