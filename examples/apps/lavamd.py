"""LavaMD-like particle potential/force computation (Rodinia).

Particles live in boxes; each box accumulates forces from its neighbor
boxes. The approximated region is the per-(box, neighbor) force kernel --
in the paper TAF gave 2.98x at 0.133% error, iACT was slower than exact
(Insight 4); hierarchical (warp) decisions improved speedup up to 2.27x
(Figure 11c). QoI: final per-particle force vectors; metric MAPE.

Elements = boxes; an element's invocation sequence enumerates its neighbor
contributions (temporal locality: neighboring boxes have similar densities).
"""
from __future__ import annotations

import time
from functools import lru_cache

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ApproxSpec, Technique, batching
from repro.core.harness import AppResult, ApproxApp
from repro.core import iact as iact_mod
from repro.core import taf as taf_mod

PPB = 16  # particles per box


def gen_boxes(nx: int = 6, seed: int = 0):
    """Grid of nx^3 boxes; returns positions (NB, PPB, 3) + neighbor ids."""
    rng = np.random.RandomState(seed)
    nb = nx ** 3
    centers = np.stack(np.meshgrid(*([np.arange(nx)] * 3),
                                   indexing="ij"), -1).reshape(-1, 3)
    pos = centers[:, None, :] + rng.uniform(0, 1, (nb, PPB, 3))
    neigh = []
    for b in range(nb):
        c = centers[b]
        ids = []
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                for dz in (-1, 0, 1):
                    q = c + np.array([dx, dy, dz])
                    if ((q >= 0) & (q < nx)).all():
                        ids.append(int(q[0] * nx * nx + q[1] * nx + q[2]))
        while len(ids) < 27:
            ids.append(b)  # pad with self (force contribution ~ small)
        neigh.append(ids)
    return pos.astype(np.float32), np.asarray(neigh, np.int32)


def pair_force(own: jnp.ndarray, other: jnp.ndarray) -> jnp.ndarray:
    """LJ-like force of `other` box particles on `own` box particles.
    own/other: (NB, PPB, 3) -> force (NB, PPB, 3)."""
    d = own[:, :, None, :] - other[:, None, :, :]       # (NB, P, P, 3)
    r2 = jnp.sum(d * d, axis=-1) + 0.25
    inv = 1.0 / r2
    mag = inv ** 4 - 0.5 * inv ** 2
    return jnp.sum(mag[..., None] * d, axis=2)


_SPECS = {}


@lru_cache(maxsize=64)
def _jitted_runner(spec_key, nx, seed):
    # the region: given flattened own+other positions per box, the force;
    # invocation t = neighbor slot t (27 per box)
    region, xs, nb = _region_setup(nx, seed)
    spec = _SPECS[spec_key]

    if spec.technique == Technique.TAF:
        def total(xs):
            ys, st, frac = taf_mod.run_sequence(spec.taf, xs, region,
                                                spec.level)
            return jnp.sum(ys, axis=0).reshape(nb, PPB, 3), frac
    elif spec.technique == Technique.IACT:
        def total(xs):
            ys, st, frac = iact_mod.run_sequence(spec.iact, xs, region,
                                                 spec.level)
            return jnp.sum(ys, axis=0).reshape(nb, PPB, 3), frac
    else:
        def total(xs):
            ys = jax.lax.map(region, xs)
            return jnp.sum(ys, axis=0).reshape(nb, PPB, 3), jnp.float32(0)
    return jax.jit(total), xs


def _region_setup(nx, seed):
    """Shared (region fn, invocation sequence, n_boxes) for both runners."""
    pos_np, neigh_np = gen_boxes(nx, seed)
    pos = jnp.asarray(pos_np)
    neigh = jnp.asarray(neigh_np)
    nb = pos.shape[0]

    def region(x):
        own = x[:, : PPB * 3].reshape(nb, PPB, 3)
        other = x[:, PPB * 3:].reshape(nb, PPB, 3)
        return pair_force(own, other).reshape(nb, PPB * 3)

    xs = jnp.concatenate([
        jnp.broadcast_to(pos.reshape(1, nb, PPB * 3), (27, nb, PPB * 3)),
        pos[neigh.T].reshape(27, nb, PPB * 3),
    ], axis=-1)
    return region, xs, nb


@lru_cache(maxsize=64)
def _group_runner(key, nx, seed):
    """Batched-runner group evaluation (core/batching.py): vmap the whole
    neighbor-sequence force accumulation over a stack of thresholds."""
    region, xs, nb = _region_setup(nx, seed)
    seq = batching.sequence_runner(key, xs, region)
    if seq is None:
        return None

    def total(th):
        ys, frac = seq(th)
        return jnp.sum(ys, axis=0).reshape(nb, PPB, 3), frac

    return jax.jit(jax.vmap(total))


def make_app(nx: int = 5, seed: int = 0) -> ApproxApp:
    def run(spec: ApproxSpec) -> AppResult:
        key = repr(spec)
        _SPECS[key] = spec
        fn, xs = _jitted_runner(key, nx, seed)
        out = fn(xs)
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        force, frac = fn(xs)
        jax.block_until_ready(force)
        wall = time.perf_counter() - t0
        frac = float(frac)
        return AppResult(qoi=np.asarray(force), wall_time_s=wall,
                         approx_fraction=frac,
                         flop_fraction=max(1.0 - frac, 1e-3))

    run_batch = batching.make_run_batch(
        run, lambda key: _group_runner(key, nx, seed))

    return ApproxApp(name="lavamd", run=run, error_metric="mape",
                     run_batch=run_batch,
                     workload=dict(nx=nx, seed=seed))
