"""Continuous-batching serving demo: many requests, few slots, TAF decode.

Run:  PYTHONPATH=src:examples python examples/continuous_batching.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.core.types import parse_pragma
from repro.models import build
from repro.serving import Request, ServingEngine


def main():
    cfg = dataclasses.replace(
        get_smoke_config("deepseek-7b"), remat=False,
        approx_decode=parse_pragma("memo(out:2:4:5.0) level(team)"))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, slots=4, max_len=64, prompt_len=8)

    rng = np.random.RandomState(0)
    n_requests = 10
    for i in range(n_requests):
        engine.submit(Request(
            uid=i, prompt=rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=int(rng.randint(4, 24))))

    t0 = time.time()
    stats = engine.run_until_drained()
    dt = time.time() - t0
    print(f"served {stats.finished}/{n_requests} requests in {dt:.2f}s "
          f"({stats.tokens_out / dt:.1f} tok/s over {stats.ticks} ticks)")
    if stats.taf_total:
        print(f"TAF skipped {stats.taf_skip_fraction:.0%} of layer-steps "
              f"(paper's output memoization as a serving feature)")


if __name__ == "__main__":
    main()
