"""Quickstart: the HPAC-Offload programming model in five minutes.

Run:  PYTHONPATH=src:examples python examples/quickstart.py

Shows: (1) pragma-style region annotation (TAF / iACT / perforation),
(2) hierarchical decision levels, (3) the DSE harness, (4) the Pallas
kernels in interpret mode.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (ApproxRegion, ApproxSpec, Level, PerforationKind,
                        PerforationParams, Technique, parse_pragma,
                        perforated_loop)
from repro.core.harness import ApproxApp, AppResult, mape, sweep, taf_grid


def main():
    # ------------------------------------------------------------------ (1)
    # A C++ HPAC-Offload pragma...
    #   #pragma approx memo(out:3:8:0.5) level(thread)
    # ...is this spec:
    spec = parse_pragma("memo(out:3:8:0.5) level(thread)")
    print("parsed spec:", spec.technique.value, spec.taf)

    # an "expensive device function" applied over a stream of invocations
    def foo(x):                       # x: (N, 4) -> (N,)
        return jnp.sum(jnp.sin(x) * jnp.cos(x) ** 2, axis=-1)

    region = ApproxRegion(spec, foo, n_elements=64, in_dim=4)
    xs = jnp.asarray(np.random.RandomState(0).standard_normal((100, 64, 4))
                     * 0.01) + 1.0    # slowly varying => TAF-friendly
    ys, frac = region.run(xs)
    exact = jax.lax.map(foo, xs)
    print(f"TAF: approximated {float(frac):.0%} of invocations, "
          f"MAPE {mape(np.asarray(exact), np.asarray(ys)):.4%}")

    # ------------------------------------------------------------------ (2)
    # herded loop perforation: structurally shorter loop, uniform control
    pspec = ApproxSpec(Technique.PERFORATION,
                       perforation=PerforationParams(
                           kind=PerforationKind.SMALL, skip=4))
    total, kept = perforated_loop(
        pspec, 32, lambda i, acc: acc + jnp.float32(i), jnp.float32(0))
    print(f"perforated sum over 32 iters (skip 1-of-4): {float(total)} "
          f"(executed {kept:.0%})")

    # ------------------------------------------------------------------ (3)
    # the DSE harness: sweep TAF parameters over an app, Figure-6 style
    def run(s: ApproxSpec) -> AppResult:
        r = ApproxRegion(s, foo, n_elements=64, in_dim=4)
        import time
        t0 = time.perf_counter()
        ys, frac = jax.jit(r.run)(xs)
        ys.block_until_ready()
        return AppResult(qoi=np.asarray(ys),
                         wall_time_s=time.perf_counter() - t0,
                         approx_fraction=float(frac),
                         flop_fraction=max(1 - float(frac), 1e-3))

    app = ApproxApp("quickstart", run)
    records = sweep(app, taf_grid(h_sizes=(2, 3), p_sizes=(8, 64),
                                  thresholds=(0.1, 1.0),
                                  levels=(Level.ELEMENT,)), repeats=1)
    best = max((r for r in records if r.error < 0.1),
               key=lambda r: r.modeled_speedup)
    print(f"best config under 10% error: {best.spec} -> "
          f"modeled {best.modeled_speedup:.2f}x at {best.error:.2%} error")

    # ------------------------------------------------------------------ (4)
    # the Pallas kernels (interpret mode on CPU)
    from repro.kernels import ops, ref
    x = jnp.asarray(np.random.RandomState(1).standard_normal(
        (256, 128)).astype(np.float32) * 0.01 + 1.0)
    w = jnp.asarray(np.random.RandomState(2).standard_normal(
        (128, 128)).astype(np.float32))
    y, mask = ops.taf_matmul(x, w, block_m=64, block_n=64,
                             rsd_threshold=1.0)
    y_ref, mask_ref = ref.taf_matmul_ref(x, w, block_m=64, block_n=64,
                                         history_size=3, prediction_size=8,
                                         rsd_threshold=1.0)
    print(f"taf_matmul kernel == oracle: "
          f"{np.allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)}; "
          f"blocks approximated: {np.asarray(mask).mean():.0%}")


if __name__ == "__main__":
    main()
