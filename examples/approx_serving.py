"""The paper's technique as a serving feature: decode-time TAF.

Run:  PYTHONPATH=src:examples python examples/approx_serving.py

Generates from a deepseek-7b-family (reduced) model twice -- exact, and
with per-layer TAF output memoization across decode steps -- and reports
the fraction of layer-steps skipped plus the divergence between the two
generations (the serving analogue of the paper's quality loss).
"""
import sys

sys.path.insert(0, "src")

import dataclasses
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.types import ApproxSpec, Level, TAFParams, Technique
from repro.launch import steps as steps_mod
from repro.models import build


def generate(cfg, params, prompts, gen, model):
    prefill = jax.jit(steps_mod.make_prefill_step(model,
                                                  prompts.shape[1] + gen))
    serve = jax.jit(steps_mod.make_serve_step(model))
    logits, cache = prefill(params, {"tokens": prompts})
    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tokens]
    skipped = total = 0
    for t in range(gen - 1):
        tokens, logits, cache = serve(params, cache, tokens,
                                      jnp.int32(prompts.shape[1] + t))
        if "taf" in cache:
            rem = np.asarray(cache["taf"]["remaining"])
            skipped += int((rem > 0).sum())
            total += rem.size
        out.append(tokens)
    return np.stack([np.asarray(t) for t in out], 1), skipped, total


def main():
    base = dataclasses.replace(get_smoke_config("deepseek-7b"),
                               remat=False, compute_dtype="float32")
    taf_cfg = dataclasses.replace(
        base, approx_decode=ApproxSpec(
            Technique.TAF, Level.BLOCK,
            taf=TAFParams(history_size=3, prediction_size=4,
                          rsd_threshold=0.2)))

    model = build(base)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = jnp.asarray(rng.randint(0, base.vocab_size, (4, 16)),
                          jnp.int32)

    exact, _, _ = generate(base, params, prompts, 24, model)
    model_taf = build(taf_cfg)
    approx, skipped, total = generate(taf_cfg, params, prompts, 24,
                                      model_taf)

    agree = float((exact == approx).mean())
    print(f"TAF decode: skipped {skipped}/{total} layer-steps "
          f"({100 * skipped / max(total, 1):.1f}%)")
    print(f"token agreement exact-vs-TAF: {agree:.0%}")
    print("exact[0]: ", exact[0, :12])
    print("approx[0]:", approx[0, :12])


if __name__ == "__main__":
    main()
