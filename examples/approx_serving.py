"""QoS quickstart: quality-guarded approximate serving (docs/qos.md).

Run:  PYTHONPATH=src:examples python examples/approx_serving.py

The closed loop in four steps:

  1. OFFLINE -- calibrate the decode workload through the ordinary sweep
     harness (`qos.make_decode_app` wraps seeded greedy generation as an
     ApproxApp; the DB is resumable like any other);
  2. POLICY  -- `QosPolicy.from_db` turns the DB's Pareto front into a
     ladder from precise to aggressive, and `choose` picks the offline
     best rung per quality target;
  3. SERVE   -- a `ServingEngine` with a `QosEngine` hook runs a seeded
     two-class request trace. "interactive" traffic carries a 1% token-
     mismatch target: no ladder rung meets that offline, so the plane
     (correctly) refuses to approximate while such a lane is live.
     "batch" traffic tolerates 80%: once only batch lanes remain, the
     engine opens the knob to batch's rung and the canaries bound the
     damage online. The TAF threshold is a traced cache entry -- every
     knob move reuses the one compiled decode step;
  4. REPORT  -- the knob trajectory, measured error vs each target, and
     latency/throughput stats.

(The tight class maps to `targets["default"]` -- the class every
unlabelled request gets.)
"""
import sys

sys.path.insert(0, "src")

import numpy as np
import jax

from repro import qos
from repro.core.harness import sweep
from repro.models import build
from repro.serving import Request, ServingEngine

TARGETS = {"default": 0.01,   # interactive: <= 1% token mismatch
           "batch": 0.80}     # throughput tier: best effort
DB_PATH = "/tmp/qos_decode_db.json"


def main():
    # 1. offline calibration sweep (re-runs are served from the DB cache)
    cfg = qos.default_decode_cfg()
    app = qos.make_decode_app(cfg, gen=12, metric="mcr")
    grid = qos.threshold_grid(cfg, (0.02, 0.04, 0.06, 0.1, 0.3))
    sweep(app, grid, repeats=1, db_path=DB_PATH)

    # 2. the policy ladder + the offline choice per target. The DB is
    #    persistent and shared, so scope to THIS app's workload
    #    fingerprint -- stale rows from runs with different sizes or a
    #    different metric must not leak into the ladder.
    policy = qos.QosPolicy.from_db(DB_PATH, app="taf_decode",
                                   workload=app.workload, metric="mcr",
                                   use_modeled=True)
    choices = {cls: policy.choose(t) for cls, t in TARGETS.items()}
    print(f"ladder ({len(policy)} rungs):")
    for i, e in enumerate(policy.entries):
        owners = ",".join(c for c, ch in choices.items() if ch.index == i)
        mark = f" <- offline choice for [{owners}]" if owners else ""
        print(f"  [{i}] thresh={e.spec.get('thresh', 'precise')}: "
              f"err={e.error:.3f} modeled={e.modeled_speedup:.2f}x{mark}")

    # 3. serve: 6 interactive requests, then 8 batch requests. While any
    #    interactive lane is live the engine actuates the strictest rung
    #    (precise); the batch-only tail runs under batch's rung.
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine_qos = qos.QosEngine(
        policy, TARGETS, sample_fraction=0.5, window=8,
        config=qos.ControllerConfig(min_samples=2, hold_ticks=2))
    eng = ServingEngine(model, params, slots=4, max_len=64, prompt_len=8,
                        qos=engine_qos)
    rng = np.random.RandomState(0)
    for i in range(14):
        eng.submit(Request(
            uid=i, prompt=rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=10, qos_class="default" if i < 6 else "batch"))
    stats = eng.run_until_drained()

    # 4. report
    print("\nactuated knob trajectory (tick: threshold; 0.0 = precise):")
    print("  " + " -> ".join(f"t{t}:{v:g}" for t, v in eng.knob_log))
    print("controller events (hold/warmup elided):")
    for cls in ("default", "batch"):
        for p in engine_qos.controllers[cls].trajectory:
            if p.event not in ("hold", "warmup", "cooldown"):
                print(f"  [{cls}] tick {p.step:3d}: rung {p.index} "
                      f"{p.event:9s} est={p.estimate:.4f}")
    s = engine_qos.summary()
    lat = stats.latency_summary()
    print(f"\nserved {stats.finished} requests, {stats.tokens_out} tokens "
          f"in {stats.ticks} ticks "
          f"({100 * stats.taf_skip_fraction:.1f}% layer-steps skipped, "
          f"{stats.knob_moves} knob moves, zero recompiles)")
    print(f"global canary error {s['genuine_mean_error']:.4f} over "
          f"{s['canary_samples']} canaries; per class (what each class's "
          "lanes were actually exposed to):")
    for cls in ("default", "batch"):
        c = s["classes"][cls]
        ok = "OK" if c["exposed_mean_error"] < TARGETS[cls] else "VIOLATED"
        print(f"  [{cls}] target={TARGETS[cls]} exposed_error="
              f"{c['exposed_mean_error']:.4f} ({ok}) over "
              f"{c['exposed_canaries']} canaries, rung {c['index']}, "
              f"fallback_rate={c['fallback_rate']:.2f}")
    print(f"ttft p50/p99: {lat['ttft_p50_s']:.3f}s/{lat['ttft_p99_s']:.3f}s, "
          f"latency p50/p99: {lat['latency_p50_s']:.3f}s/"
          f"{lat['latency_p99_s']:.3f}s")


if __name__ == "__main__":
    main()
